"""Paper Fig. 6: end-to-end training time to a target test accuracy —
ScaleGNN (4D, uniform sampling) vs the baseline algorithms (GraphSAINT-node
DP, GraphSAGE neighbor sampling DP) — plus a scan-chunk ablation of the
``repro.train`` runtime (per-step wall time at chunk sizes 1/8/32, putting
the per-step Python-dispatch overhead win on the record).

Per the paper's methodology (§VI-C) epoch times are NOT comparable across
sampling algorithms; wall-clock to target accuracy is.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv, set_bench
from repro.core import baselines as BL
from repro.core import fourd, gcn_model as M, sampling as S
from repro.graphs import build_partitioned_graph, make_synthetic_dataset
from repro.obs import Tracer
from repro.optim import AdamW
from repro.train import Trainer, TrainLoopConfig

TARGET = 0.88
MAX_STEPS = 400
B = 256
ABLATION_STEPS = 64                   # divisible by every chunk size below
ABLATION_CHUNKS = (1, 8, 32)
TRACER_REPS = 5                       # alternating on/off reps (medians)


def main():
    set_bench("fig6", n=2048, batch=B, target=TARGET,
              max_steps=MAX_STEPS)
    ds = make_synthetic_dataset(n=2048, num_classes=8, d_in=32,
                                avg_degree=16, seed=7)
    A = ds.adj_norm
    g = {"rp": jnp.array(A.indptr), "ci": jnp.array(A.indices),
         "val": jnp.array(A.data), "feats": jnp.array(ds.features),
         "labels": jnp.array(ds.labels),
         "deg": jnp.array(A.row_degrees().astype(np.float32)),
         "e_cap": B * A.max_row_nnz(), "n": ds.num_vertices}
    from repro.graphs import csr_to_dense
    dense = jnp.array(csr_to_dense(A))
    test = jnp.array(ds.test_mask)

    def eval_acc(params, cfg):
        logits = M.forward(params, dense, g["feats"], cfg, train=False)
        return float(M.accuracy(logits, g["labels"], test))

    # --- ScaleGNN: 4D parallel (DP2 x 2^3 grid = 16... we have 8 devs ->
    # DP1 x 2^3), uniform sampling, all optimizations on, driven by the
    # scan-chunked repro.train runtime (one eval per report boundary)
    pg = build_partitioned_graph(ds, g=2)
    cfg4 = M.GCNConfig(d_in=32, d_hidden=96, num_layers=3, num_classes=8,
                       dropout=0.2)
    mesh = fourd.make_mesh_4d(1, 2)
    opts = fourd.TrainOptions(dropout=0.2, bf16_collectives=True)
    plan = fourd.build_plan(pg, cfg4, mesh, batch=B, opts=opts)
    # chunk buffers are donated, so every run needs fresh initial params
    fresh4 = lambda: plan.shard_params(
        M.init_params(jax.random.PRNGKey(0), cfg4))
    graph = plan.shard_graph(pg)
    opt = AdamW(lr=5e-3, weight_decay=1e-4)
    trainer = Trainer(plan, opt, TrainLoopConfig(
        total_steps=MAX_STEPS, chunk_size=20, eval_every=20,
        target_acc=TARGET))
    trainer.compiled_chunk(20)(trainer.init_state(fresh4(), graph),
                               graph)            # compile
    t0 = time.time()
    state, log = trainer.run(trainer.init_state(fresh4(), graph), graph)
    t_hit = (time.time() - t0) if log.hit_target else None
    steps_hit = int(state.step) if log.hit_target else None
    csv("fig6_scalegnn_4d", (t_hit or (time.time() - t0)) * 1e6,
        f"steps={steps_hit} target={TARGET}")

    # --- scan-chunk ablation: per-step wall time vs steps-per-dispatch.
    # chunk=1 pays one host dispatch per optimizer step (the legacy loop);
    # larger chunks amortize it inside one lax.scan.
    for chunk in ABLATION_CHUNKS:
        tr = Trainer(plan, opt, TrainLoopConfig(
            total_steps=ABLATION_STEPS, chunk_size=chunk))
        tr.run(tr.init_state(fresh4(), graph), graph)        # compile
        timed_state = tr.init_state(fresh4(), graph)
        t0 = time.perf_counter()
        tr.run(timed_state, graph)
        dt = time.perf_counter() - t0
        csv(f"fig6_scan_chunk{chunk}", dt / ABLATION_STEPS * 1e6,
            f"steps={ABLATION_STEPS} per-step")

    # --- tracer overhead: identical runs with host spans on vs off. The
    # spans sit at chunk boundaries (one perf_counter pair per chunk), so
    # the two ms/step figures must agree within noise (<2% acceptance).
    # Run-to-run spread on a loaded host is ~8%, so a single pair proves
    # nothing: take the median over alternating repeats of each mode.
    trainers = {}
    for mode, enabled in (("off", False), ("on", True)):
        tr = Trainer(plan, opt,
                     TrainLoopConfig(total_steps=ABLATION_STEPS,
                                     chunk_size=8),
                     tracer=Tracer(enabled=enabled))
        tr.run(tr.init_state(fresh4(), graph), graph)        # compile
        trainers[mode] = tr
    reps = {"off": [], "on": []}
    for _ in range(TRACER_REPS):
        for mode, tr in trainers.items():
            _, tlog = tr.run(tr.init_state(fresh4(), graph), graph)
            reps[mode].append(tlog.ms_per_step)
    ms = {mode: float(np.median(xs)) for mode, xs in reps.items()}
    for mode, xs in reps.items():
        csv(f"fig6_tracer_{mode}", ms[mode] * 1e3,
            f"steps={ABLATION_STEPS} reps={TRACER_REPS} "
            f"spread={min(xs):.2f}..{max(xs):.2f}ms")
    overhead = (ms["on"] - ms["off"]) / ms["off"] * 100
    print(f"# tracer overhead: {overhead:+.2f}% ms/step, median of "
          f"{TRACER_REPS} alternating reps (acceptance: |overhead| < 2%)")

    # --- baselines (single device, the algorithms of the baseline systems)
    for name in ("saint", "sage"):
        cfg = M.GCNConfig(d_in=32, d_hidden=96,
                          num_layers=2 if name == "sage" else 3,
                          num_classes=8, dropout=0.2)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        opt_state = opt.init(params)

        @jax.jit
        def step(p_, o_, i):
            key = S.step_key(3, i)
            if name == "saint":
                sb = BL.saint_node_sample(
                    key, g["rp"], g["ci"], g["val"], g["feats"],
                    g["labels"], g["deg"], g["n"], B, g["e_cap"])
                def loss_fn(pp):
                    lg = M.forward(pp, sb.adj, sb.feats, cfg,
                                   dropout_key=key, train=True)
                    return M.cross_entropy_loss(lg, sb.labels,
                                                sb.loss_weights)
            else:
                sgb = BL.sage_sample(key, g["rp"], g["ci"], g["feats"],
                                     g["labels"], g["n"], B, [10, 10])
                def loss_fn(pp):
                    lg = M.sage_forward(pp, sgb, cfg, dropout_key=key,
                                        train=True)
                    return M.cross_entropy_loss(lg, sgb.labels)
            loss, grads = jax.value_and_grad(loss_fn)(p_)
            p2, o2 = opt.update(p_, grads, o_)
            return p2, o2, loss

        step(params, opt_state, jnp.asarray(0))
        t0 = time.time()
        t_hit, steps_hit = None, None
        for i in range(MAX_STEPS):
            params, opt_state, _ = step(params, opt_state, jnp.asarray(i))
            if i % 20 == 19 and eval_acc(params, cfg) >= TARGET:
                t_hit, steps_hit = time.time() - t0, i + 1
                break
        csv(f"fig6_{name}_dp", (t_hit or (time.time() - t0)) * 1e6,
            f"steps={steps_hit} target={TARGET}")


if __name__ == "__main__":
    main()
