"""Paper Fig. 6: end-to-end training time to a target test accuracy —
ScaleGNN (4D, uniform sampling) vs the baseline algorithms (GraphSAINT-node
DP, GraphSAGE neighbor sampling DP).

Per the paper's methodology (§VI-C) epoch times are NOT comparable across
sampling algorithms; wall-clock to target accuracy is.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv
from repro.core import baselines as BL
from repro.core import fourd, gcn_model as M, sampling as S
from repro.graphs import build_partitioned_graph, make_synthetic_dataset
from repro.optim import AdamW

TARGET = 0.88
MAX_STEPS = 400
B = 256


def main():
    ds = make_synthetic_dataset(n=2048, num_classes=8, d_in=32,
                                avg_degree=16, seed=7)
    A = ds.adj_norm
    g = {"rp": jnp.array(A.indptr), "ci": jnp.array(A.indices),
         "val": jnp.array(A.data), "feats": jnp.array(ds.features),
         "labels": jnp.array(ds.labels),
         "deg": jnp.array(A.row_degrees().astype(np.float32)),
         "e_cap": B * A.max_row_nnz(), "n": ds.num_vertices}
    from repro.graphs import csr_to_dense
    dense = jnp.array(csr_to_dense(A))
    test = jnp.array(ds.test_mask)

    def eval_acc(params, cfg):
        logits = M.forward(params, dense, g["feats"], cfg, train=False)
        return float(M.accuracy(logits, g["labels"], test))

    # --- ScaleGNN: 4D parallel (DP2 x 2^3 grid = 16... we have 8 devs ->
    # DP1 x 2^3), uniform sampling, all optimizations on
    pg = build_partitioned_graph(ds, g=2)
    cfg4 = M.GCNConfig(d_in=32, d_hidden=96, num_layers=3, num_classes=8,
                       dropout=0.2)
    mesh = fourd.make_mesh_4d(1, 2)
    opts = fourd.TrainOptions(dropout=0.2, bf16_collectives=True)
    plan = fourd.build_plan(pg, cfg4, mesh, batch=B, opts=opts)
    params = plan.shard_params(M.init_params(jax.random.PRNGKey(0), cfg4))
    graph = plan.shard_graph(pg)
    opt = AdamW(lr=5e-3, weight_decay=1e-4)
    opt_state = opt.init(params)
    train_step = fourd.make_train_step(plan, opt)
    eval_step = fourd.make_eval_step(plan)
    train_step(params, opt_state, graph, jnp.asarray(0))  # compile
    t0 = time.time()
    t_hit, steps_hit = None, None
    p, o = params, opt_state
    for i in range(MAX_STEPS):
        p, o, _ = train_step(p, o, graph, jnp.asarray(i))
        if i % 20 == 19 and float(eval_step(p, graph)) >= TARGET:
            t_hit, steps_hit = time.time() - t0, i + 1
            break
    csv("fig6_scalegnn_4d", (t_hit or (time.time() - t0)) * 1e6,
        f"steps={steps_hit} target={TARGET}")

    # --- baselines (single device, the algorithms of the baseline systems)
    for name in ("saint", "sage"):
        cfg = M.GCNConfig(d_in=32, d_hidden=96,
                          num_layers=2 if name == "sage" else 3,
                          num_classes=8, dropout=0.2)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        opt_state = opt.init(params)

        @jax.jit
        def step(p_, o_, i):
            key = S.step_key(3, i)
            if name == "saint":
                sb = BL.saint_node_sample(
                    key, g["rp"], g["ci"], g["val"], g["feats"],
                    g["labels"], g["deg"], g["n"], B, g["e_cap"])
                def loss_fn(pp):
                    lg = M.forward(pp, sb.adj, sb.feats, cfg,
                                   dropout_key=key, train=True)
                    return M.cross_entropy_loss(lg, sb.labels,
                                                sb.loss_weights)
            else:
                sgb = BL.sage_sample(key, g["rp"], g["ci"], g["feats"],
                                     g["labels"], g["n"], B, [10, 10])
                def loss_fn(pp):
                    lg = M.sage_forward(pp, sgb, cfg, dropout_key=key,
                                        train=True)
                    return M.cross_entropy_loss(lg, sgb.labels)
            loss, grads = jax.value_and_grad(loss_fn)(p_)
            p2, o2 = opt.update(p_, grads, o_)
            return p2, o2, loss

        step(params, opt_state, jnp.asarray(0))
        t0 = time.time()
        t_hit, steps_hit = None, None
        for i in range(MAX_STEPS):
            params, opt_state, _ = step(params, opt_state, jnp.asarray(i))
            if i % 20 == 19 and eval_acc(params, cfg) >= TARGET:
                t_hit, steps_hit = time.time() - t0, i + 1
                break
        csv(f"fig6_{name}_dp", (t_hit or (time.time() - t0)) * 1e6,
            f"steps={steps_hit} target={TARGET}")


if __name__ == "__main__":
    main()
