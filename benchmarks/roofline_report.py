"""Roofline report: three terms per (arch x shape x mesh) from the saved
dry-run records, MODEL_FLOPS/HLO_FLOPs utilization ratio, dominant
bottleneck, and a per-row what-would-move-it-down note.

Reads ``experiments/dryrun/*.json`` (produce with
``python -m repro.launch.dryrun``); re-analyzes nothing, so it runs on one
device in seconds. Also emits ``experiments/roofline.md`` consumed by
EXPERIMENTS.md.
"""
from __future__ import annotations

import os

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.roofline import (HBM_BW, ICI_BW_PER_LINK, ICI_LINKS,
                                   PEAK_FLOPS, load_dryrun_records,
                                   model_flops, roofline_terms)

HERE = os.path.dirname(os.path.abspath(__file__))
DRYRUN_DIR = os.path.join(HERE, "..", "experiments", "dryrun")
OUT_MD = os.path.join(HERE, "..", "experiments", "roofline.md")

ADVICE = {
    "compute": "compute-bound: raise MXU utilization (larger per-device "
               "tiles, bf16 end-to-end); already near the best regime",
    "memory": "memory-bound: fuse the flash-attention streams into a "
              "Pallas kernel (q re-read per KV block dominates), keep "
              "activations bf16, increase arithmetic intensity via larger "
              "microbatches",
    "collective": "collective-bound: overlap all-gathers with layer "
                  "compute (FSDP prefetch), shard KV heads instead of "
                  "head_dim, or move to 2-pod DP to halve per-group "
                  "gradient volume",
}


def main():
    recs = load_dryrun_records(DRYRUN_DIR)
    rows = []
    for r in recs:
        if r.get("status") != "ok" or "loop_aware" not in r:
            continue
        la = r["loop_aware"]
        terms = roofline_terms(la)
        arch, shape_name = r["arch"], r["shape"]
        try:
            cfg = get_config(arch)
            mf = model_flops(cfg, INPUT_SHAPES[shape_name],
                             r["n_devices"])
            ratio = mf / la["flops"] if la["flops"] else 0.0
        except Exception:
            ratio = 0.0
        rows.append({
            "arch": arch, "shape": shape_name, "mesh": r["mesh"],
            "t_compute": terms["t_compute_s"],
            "t_memory": terms["t_memory_s"],
            "t_collective": terms["t_collective_s"],
            "dominant": terms["dominant"],
            "useful_ratio": ratio,
            "temp_gib": r["memory"]["temp_bytes"] / 2 ** 30,
        })

    rows.sort(key=lambda x: (x["arch"], x["shape"], x["mesh"]))
    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':6s} {'t_comp(s)':>10s} "
           f"{'t_mem(s)':>10s} {'t_coll(s)':>10s} {'dominant':>10s} "
           f"{'6ND/HLO':>8s} {'temp GiB':>9s}")
    print(hdr)
    md = ["| arch | shape | mesh | t_compute (s) | t_memory (s) | "
          "t_collective (s) | dominant | 6ND/HLO | temp GiB |",
          "|---|---|---|---|---|---|---|---|---|"]
    for x in rows:
        line = (f"{x['arch']:26s} {x['shape']:12s} {x['mesh']:6s} "
                f"{x['t_compute']:10.4f} {x['t_memory']:10.4f} "
                f"{x['t_collective']:10.4f} {x['dominant']:>10s} "
                f"{x['useful_ratio']:8.3f} {x['temp_gib']:9.2f}")
        print(line)
        md.append(f"| {x['arch']} | {x['shape']} | {x['mesh']} | "
                  f"{x['t_compute']:.4f} | {x['t_memory']:.4f} | "
                  f"{x['t_collective']:.4f} | {x['dominant']} | "
                  f"{x['useful_ratio']:.3f} | {x['temp_gib']:.2f} |")
        csv_name = f"roofline_{x['arch']}_{x['shape']}_{x['mesh']}"
        dom_t = max(x["t_compute"], x["t_memory"], x["t_collective"])
        print(f"{csv_name},{dom_t * 1e6:.1f},dominant={x['dominant']} "
              f"ratio={x['useful_ratio']:.3f}")

    by_dom = {}
    for x in rows:
        by_dom.setdefault(x["dominant"], []).append(x)
    md.append("")
    for dom, xs in by_dom.items():
        md.append(f"**{dom}-bound ({len(xs)} rows)** — {ADVICE[dom]}")
        md.append("")
    with open(OUT_MD, "w") as f:
        f.write("\n".join(md) + "\n")
    print(f"# wrote {OUT_MD} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
