"""Paper Table I: test accuracy of uniform vertex sampling (ScaleGNN) vs
GraphSAINT-node and GraphSAGE, same model/optimizer/budget.

The OGB datasets are replaced by an SBM stand-in whose labels require
structure to learn (DESIGN.md §9.2); the claim under test is the paper's
RELATIVE ordering: uniform sampling with unbiased rescaling matches or
exceeds both baselines.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv, set_bench
from repro.core import baselines as BL
from repro.core import gcn_model as M
from repro.core import sampling as S
from repro.graphs import make_synthetic_dataset
from repro.optim import AdamW

STEPS = 200
B = 384


def setup():
    ds = make_synthetic_dataset(n=2048, num_classes=8, d_in=32,
                                avg_degree=16, feature_noise=3.5,
                                p_in_out_ratio=6.0, seed=7)
    A = ds.adj_norm
    return ds, {
        "rp": jnp.array(A.indptr), "ci": jnp.array(A.indices),
        "val": jnp.array(A.data),
        "feats": jnp.array(ds.features), "labels": jnp.array(ds.labels),
        "deg": jnp.array(A.row_degrees().astype(np.float32)),
        "e_cap": B * A.max_row_nnz(), "n": ds.num_vertices,
    }


def eval_acc(ds, params, cfg):
    from repro.graphs import csr_to_dense
    dense = jnp.array(csr_to_dense(ds.adj_norm))
    feats = jnp.array(ds.features)
    logits = M.forward(params, dense, feats, cfg, train=False)
    test = jnp.array(ds.test_mask)
    return float(M.accuracy(logits, jnp.array(ds.labels), test))


def train(method: str, ds, g):
    cfg = M.GCNConfig(d_in=32, d_hidden=96,
                      num_layers=2 if method == "sage" else 3,
                      num_classes=8, dropout=0.2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=5e-3, weight_decay=1e-4)
    opt_state = opt.init(params)

    @jax.jit
    def step_uniform(p, o, i):
        key = S.step_key(0, i)
        mb = S.make_minibatch_exact(key, g["rp"], g["ci"], g["val"],
                                    g["feats"], g["labels"], g["n"], B,
                                    g["e_cap"])
        def loss_fn(pp):
            lg = M.forward(pp, mb.adj, mb.feats, cfg, dropout_key=key,
                           train=True)
            return M.cross_entropy_loss(lg, mb.labels)
        loss, grads = jax.value_and_grad(loss_fn)(p)
        p2, o2 = opt.update(p, grads, o)
        return p2, o2, loss

    @jax.jit
    def step_saint(p, o, i):
        key = S.step_key(1, i)
        sb = BL.saint_node_sample(key, g["rp"], g["ci"], g["val"],
                                  g["feats"], g["labels"], g["deg"],
                                  g["n"], B, g["e_cap"])
        def loss_fn(pp):
            lg = M.forward(pp, sb.adj, sb.feats, cfg, dropout_key=key,
                           train=True)
            return M.cross_entropy_loss(lg, sb.labels, sb.loss_weights)
        loss, grads = jax.value_and_grad(loss_fn)(p)
        p2, o2 = opt.update(p, grads, o)
        return p2, o2, loss

    @jax.jit
    def step_sage(p, o, i):
        key = S.step_key(2, i)
        sgb = BL.sage_sample(key, g["rp"], g["ci"], g["feats"],
                             g["labels"], g["n"], B, [10, 10])
        def loss_fn(pp):
            lg = M.sage_forward(pp, sgb, cfg, dropout_key=key, train=True)
            return M.cross_entropy_loss(lg, sgb.labels)
        loss, grads = jax.value_and_grad(loss_fn)(p)
        p2, o2 = opt.update(p, grads, o)
        return p2, o2, loss

    step = {"uniform": step_uniform, "saint": step_saint,
            "sage": step_sage}[method]
    best = 0.0
    t0 = time.time()
    for i in range(STEPS):
        params, opt_state, _ = step(params, opt_state, jnp.asarray(i))
        if i % 40 == 39:
            best = max(best, eval_acc(ds, params, cfg))
    return best, time.time() - t0


def main():
    set_bench("table1", steps=STEPS, batch=B)
    ds, g = setup()
    results = {}
    for method in ("uniform", "saint", "sage"):
        acc, dt = train(method, ds, g)
        results[method] = acc
        csv(f"table1_{method}_test_acc", dt / STEPS * 1e6,
            f"acc={acc:.4f}")
    # the paper's claim: uniform >= max(baselines) - small margin
    print(f"# uniform={results['uniform']:.4f} "
          f"saint={results['saint']:.4f} sage={results['sage']:.4f}")
    assert results["uniform"] >= max(results["saint"],
                                     results["sage"]) - 0.05


if __name__ == "__main__":
    main()
