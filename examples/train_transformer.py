"""Train a reduced assigned-architecture config on the synthetic token
stream — exercises the transformer substrate end-to-end (data pipeline,
AdamW, checkpointing) on one device.

    PYTHONPATH=src python examples/train_transformer.py --arch tinyllama-1.1b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.configs import ARCH_IDS, get_smoke
from repro.data import TokenStream
from repro.models import transformer as T
from repro.optim import AdamW, linear_warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit(f"{args.arch}: LM pretraining example targets "
                         "decoder-only families; the multimodal stubs are "
                         "exercised by the dry-run and smoke tests")
    print(f"training {cfg.name} ({cfg.family}) on synthetic tokens")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"parameters: {n_params:,}")

    stream = TokenStream(vocab_size=cfg.vocab, batch=args.batch,
                         seq_len=args.seq, seed=0, coherence=0.8)
    opt = AdamW(lr=linear_warmup_cosine(3e-3, 10, args.steps),
                grad_clip=1.0)
    opt_state = opt.init(params)

    @jax.jit
    def train_step(p, o, toks, tgts):
        def loss_fn(pp):
            logits, aux = T.forward_train(pp, toks, cfg)
            return T.lm_loss(logits, tgts, cfg.vocab) \
                + 0.01 * jnp.asarray(aux, jnp.float32)
        loss, grads = jax.value_and_grad(loss_fn)(p)
        p2, o2 = opt.update(p, grads, o)
        return p2, o2, loss

    t0 = time.time()
    first = None
    for step in range(args.steps):
        toks, tgts = stream.batch_at(step)
        params, opt_state, loss = train_step(
            params, opt_state, jnp.asarray(toks), jnp.asarray(tgts))
        if first is None:
            first = float(loss)
        if step % 25 == 0:
            print(f"step {step:4d}  loss {float(loss):.4f}  "
                  f"t={time.time()-t0:.1f}s")
    print(f"loss: {first:.3f} -> {float(loss):.3f} "
          f"(planted bigram structure is learnable)")
    assert float(loss) < first, "no learning happened"
    if args.ckpt_dir:
        print("saved:", save_checkpoint(args.ckpt_dir, args.steps,
                                        jax.device_get(params)))


if __name__ == "__main__":
    main()
