"""End-to-end GNN serving: train a small GCN on a synthetic ogbn-products
stand-in (Alg. 1 mini-batch loop), then serve a stream of "classify these
vertex IDs" requests through the micro-batched inference engine.

    PYTHONPATH=src python examples/serve_gnn.py
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gcn_model as M
from repro.core import sampling as S
from repro.graphs import get_dataset
from repro.optim import AdamW
from repro.serve import InferenceEngine, ServeOptions


def train(ds, cfg, steps: int, batch: int = 256):
    A = ds.adj_norm
    rp, ci, val = (jnp.array(A.indptr), jnp.array(A.indices),
                   jnp.array(A.data))
    feats, labels = jnp.array(ds.features), jnp.array(ds.labels)
    n, e_cap = ds.num_vertices, batch * A.max_row_nnz()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=5e-3, weight_decay=1e-4)
    opt_state = opt.init(params)

    @jax.jit
    def train_step(params, opt_state, step):
        key = S.step_key(0, step)
        mb = S.make_minibatch_exact(key, rp, ci, val, feats, labels,
                                    n, batch, e_cap)
        def loss_fn(p):
            logits = M.forward(p, mb.adj, mb.feats, cfg, dropout_key=key,
                               train=True)
            return M.cross_entropy_loss(logits, mb.labels)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, loss

    for step in range(steps):
        params, opt_state, loss = train_step(params, opt_state,
                                             jnp.asarray(step))
        if step % 50 == 0:
            print(f"train step {step:4d}  loss {float(loss):.4f}")
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=2048)
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--slots", type=int, default=32)
    ap.add_argument("--support", type=int, default=224)
    ap.add_argument("--cache", action="store_true")
    args = ap.parse_args()

    ds = get_dataset("ogbn-products", scale_vertices=args.vertices, seed=0)
    cfg = M.GCNConfig(d_in=ds.feature_dim, d_hidden=128, num_layers=2,
                      num_classes=ds.num_classes, dropout=0.2)
    params = train(ds, cfg, args.train_steps)

    eng = InferenceEngine(
        params, cfg, ds.adj_norm, ds.features,
        ServeOptions(slots=args.slots, support=args.support,
                     max_delay_ms=2.0, use_cache=args.cache))

    eng.predict([0])            # jit warmup (one compile for all traffic)
    eng.reset_stats()

    # a Zipfian request stream (hot vertices dominate, as in real serving)
    rng = np.random.default_rng(7)
    zipf = np.minimum(rng.zipf(1.3, size=args.requests),
                      ds.num_vertices) - 1
    print(f"\nserving {args.requests} single-vertex requests "
          f"(slots={args.slots}, support={args.support}, "
          f"cache={'on' if args.cache else 'off'}) ...")
    rids = []
    t0 = time.monotonic()
    for v in zipf:
        rids.append((eng.submit([int(v)]), int(v)))
        eng.pump()
    eng.drain()
    dt = time.monotonic() - t0

    correct = total = 0
    for rid, v in rids:
        out = eng.poll(rid)
        assert out is not None
        correct += int(np.argmax(out[0]) == ds.labels[v])
        total += 1
    st = eng.stats()
    print(f"served {total} requests in {dt*1e3:.1f} ms "
          f"({total/dt:.0f} req/s, {st['device_calls']} device calls)")
    print(f"latency p50 {st['p50_ms']:.2f} ms  p99 {st['p99_ms']:.2f} ms")
    if "cache" in st:
        print(f"cache hit rate {st['cache']['hit_rate']:.2f}")
    print(f"online accuracy vs labels: {correct/total:.4f}")


if __name__ == "__main__":
    main()
