"""LLM serving example: drive autoregressive decoding through the SAME
``ServingDriver`` that fronts GNN classification — prompts submitted from
client code as futures, KV-cache slot scheduling + continuous batching
behind the protocol seam.

    PYTHONPATH=src python examples/serve_llm.py --arch tinyllama-1.1b

``--legacy-loop`` runs the original hand-rolled batch prefill/decode loop
instead; it remains the only path for families whose decode state is not
slot-scheduled yet (ssm/hybrid/vlm/audio) and doubles as the golden
reference the serving tests compare greedy outputs against.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke
from repro.models import transformer as T
from repro.serve import LLMEngine, LLMServeOptions, ServingDriver


def legacy_loop(cfg, params, args, rng):
    """Static-batch prefill + decode with the scalar-pos cache API."""
    B, S = args.batch, args.prompt_len
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    mem = None
    if cfg.family == "vlm":
        mem = jnp.asarray(rng.normal(size=(B, cfg.n_image_tokens,
                                           cfg.d_model)), cfg.compute_dtype)
    if cfg.family == "audio":
        mem = jnp.asarray(rng.normal(size=(B, cfg.encoder.n_frames,
                                           cfg.d_model)), cfg.compute_dtype)

    prefill = jax.jit(lambda p, t, m: T.prefill(
        p, t, cfg, max_len=S + args.new_tokens, memory=m))
    decode = jax.jit(lambda p, t, c: T.decode_step(p, t, c, cfg))

    t0 = time.time()
    logits, cache = prefill(params, prompts, mem)
    jax.block_until_ready(logits)
    print(f"{cfg.name}: prefill {B}x{S} in {(time.time() - t0) * 1e3:.1f} ms")

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    outs = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = np.concatenate([np.array(o) for o in outs], axis=1)
    print(f"decoded {args.new_tokens} tokens/seq in {dt*1e3:.1f} ms "
          f"({B * args.new_tokens / dt:.0f} tok/s batch throughput)")
    print("sample token ids:", gen[0][:16].tolist())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4,
                    help="KV cache pool size (driver path)")
    ap.add_argument("--legacy-loop", action="store_true",
                    help="bypass the driver: hand-rolled batch loop "
                         "(required for ssm/hybrid/vlm/audio families)")
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    rng = np.random.default_rng(0)
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    if args.legacy_loop or cfg.family not in ("dense", "moe"):
        if not args.legacy_loop:
            print(f"[{cfg.family} family has no slot scheduling yet; "
                  f"falling back to --legacy-loop]")
        legacy_loop(cfg, params, args, rng)
        return

    engine = LLMEngine(params, cfg, LLMServeOptions(
        slots=args.slots, max_prompt_len=args.prompt_len,
        max_new_tokens=args.new_tokens))
    prompts = [rng.integers(0, cfg.vocab, args.prompt_len).tolist()
               for _ in range(args.batch)]

    t0 = time.time()
    with ServingDriver(engine, starvation_ms=5.0) as drv:
        futs = [drv.submit(p) for p in prompts]
        outs = [f.result(timeout=120) for f in futs]
        st = drv.stats()
    dt = time.time() - t0

    total = sum(len(o) for o in outs)
    print(f"{cfg.name}: {args.batch} prompts x {args.prompt_len} tokens "
          f"through ServingDriver ({args.slots} slots)")
    print(f"generated {total} tokens in {dt*1e3:.1f} ms "
          f"({total / dt:.0f} tok/s), "
          f"prefills={st['prefills']} decode_steps={st['decode_steps']} "
          f"occupancy={st['slot_occupancy']:.2f} "
          f"decode_compiles={st['decode_compiles']}")
    print("sample token ids:", np.asarray(outs[0])[:16].tolist())


if __name__ == "__main__":
    main()
