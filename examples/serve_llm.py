"""Batched serving example: prefill a batch of prompts, then decode tokens
with the per-family KV cache / SSM state machinery — the same code paths
the decode_32k / long_500k dry-run shapes exercise.

    PYTHONPATH=src python examples/serve_llm.py --arch mixtral-8x7b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    rng = np.random.default_rng(0)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S = args.batch, args.prompt_len
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    mem = None
    if cfg.family == "vlm":
        mem = jnp.asarray(rng.normal(size=(B, cfg.n_image_tokens,
                                           cfg.d_model)), cfg.compute_dtype)
    if cfg.family == "audio":
        mem = jnp.asarray(rng.normal(size=(B, cfg.encoder.n_frames,
                                           cfg.d_model)), cfg.compute_dtype)

    prefill = jax.jit(lambda p, t, m: T.prefill(
        p, t, cfg, max_len=S + args.new_tokens, memory=m))
    decode = jax.jit(lambda p, t, c: T.decode_step(p, t, c, cfg))

    t0 = time.time()
    logits, cache = prefill(params, prompts, mem)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"{cfg.name}: prefill {B}x{S} in {t_prefill*1e3:.1f} ms")

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    outs = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = np.concatenate([np.array(o) for o in outs], axis=1)
    print(f"decoded {args.new_tokens} tokens/seq in {dt*1e3:.1f} ms "
          f"({B * args.new_tokens / dt:.0f} tok/s batch throughput)")
    print("sample token ids:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
