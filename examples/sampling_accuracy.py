"""Paper Table I reproduction at example scale: uniform vertex sampling vs
GraphSAINT-node vs GraphSAGE, identical model/budget.

    PYTHONPATH=src:. python examples/sampling_accuracy.py
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.table1_sampling_accuracy import main   # noqa: E402

if __name__ == "__main__":
    main()
