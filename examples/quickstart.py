"""Quickstart: ScaleGNN mini-batch training on one device in ~30 seconds.

Demonstrates the paper's core loop (uniform vertex sampling -> induced
subgraph with unbiased rescaling -> GCN step, Alg. 1) on a synthetic SBM
stand-in for ogbn-products, built through the unified batch-construction
layer (``repro.core.minibatch.MinibatchBuilder``).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import gcn_model as M
from repro.core import sampling as S
from repro.core.minibatch import MinibatchBuilder
from repro.graphs import csr_to_dense, get_dataset
from repro.optim import AdamW


def main():
    ds = get_dataset("ogbn-products", scale_vertices=2048, seed=0)
    A = ds.adj_norm
    rp, ci, val = (jnp.array(A.indptr), jnp.array(A.indices),
                   jnp.array(A.data))
    feats, labels = jnp.array(ds.features), jnp.array(ds.labels)
    n, B = ds.num_vertices, 256
    e_cap = B * A.max_row_nnz()

    cfg = M.GCNConfig(d_in=ds.feature_dim, d_hidden=128, num_layers=3,
                      num_classes=ds.num_classes, dropout=0.2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=5e-3, weight_decay=1e-4)
    opt_state = opt.init(params)

    # Alg. 1 behind the one batch-construction layer: swap mode to
    # "stratified", fmt to ELL, or impl to "pallas" without touching the
    # training loop.
    builder = MinibatchBuilder(
        scfg=S.SampleConfig(n_pad=n, g=1, batch=B, e_cap=e_cap),
        mode="exact")

    @jax.jit
    def train_step(params, opt_state, step):
        key = S.step_key(0, step)                       # shared seed + step
        mb = builder.build_single(key, rp, ci, val, feats, labels)
        def loss_fn(p):
            logits = M.forward(p, mb.adj, mb.feats, cfg, dropout_key=key,
                               train=True)
            return M.cross_entropy_loss(logits, mb.labels)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, loss

    dense = jnp.array(csr_to_dense(A))
    test = jnp.array(ds.test_mask)
    for step in range(200):
        params, opt_state, loss = train_step(params, opt_state,
                                             jnp.asarray(step))
        if step % 50 == 0:
            logits = M.forward(params, dense, feats, cfg, train=False)
            acc = float(M.accuracy(logits, labels, test))
            print(f"step {step:4d}  loss {float(loss):.4f}  "
                  f"test acc {acc:.4f}")
    logits = M.forward(params, dense, feats, cfg, train=False)
    print(f"final test accuracy: "
          f"{float(M.accuracy(logits, labels, test)):.4f}")


if __name__ == "__main__":
    main()
