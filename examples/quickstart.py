"""Quickstart: ScaleGNN mini-batch training on one device in ~30 seconds.

Demonstrates the paper's core loop (communication-free vertex sampling ->
induced subgraph with unbiased rescaling -> GCN step) on a synthetic SBM
stand-in for ogbn-products — through the SAME machinery the 16-device runs
use, shrunk to a 1x1x1x1 mesh: the unified batch construction
(``core.minibatch.MinibatchBuilder``), the one forward engine
(``core.forward.ForwardEngine``), and the scan-chunked ``repro.train``
runtime (8 optimizer steps per host dispatch, one eval per report).
Swap ``--gd/--g`` on ``repro.launch.train`` and the identical program
scales out.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import fourd, gcn_model as M
from repro.graphs import build_partitioned_graph, get_dataset
from repro.optim import AdamW
from repro.train import Trainer, TrainLoopConfig


def main():
    ds = get_dataset("ogbn-products", scale_vertices=2048, seed=0)
    pg = build_partitioned_graph(ds, g=1)
    cfg = M.GCNConfig(d_in=ds.feature_dim, d_hidden=128, num_layers=3,
                      num_classes=ds.num_classes, dropout=0.2)
    mesh = fourd.make_mesh_4d(1, 1)                  # one device, same code
    # sample_mode="epoch": without-replacement — each epoch permutes the
    # vertex set once and every step takes the next slice (communication-
    # free, a pure function of (seed, epoch, step)); n/batch steps = 1 epoch
    plan = fourd.build_plan(pg, cfg, mesh, batch=256,
                            opts=fourd.TrainOptions(dropout=0.2,
                                                    sample_mode="epoch"))

    graph = plan.shard_graph(pg)
    opt = AdamW(lr=5e-3, weight_decay=1e-4)
    trainer = Trainer(plan, opt, TrainLoopConfig(
        epochs=25, chunk_size=8, eval_every=48))
    state = trainer.init_state(
        plan.shard_params(M.init_params(jax.random.PRNGKey(0), cfg)), graph)

    def report(step, loss, acc):
        print(f"step {step:4d}  loss {loss:.4f}  full-graph acc {acc:.4f}")

    state, log = trainer.run(state, graph, report=report)
    print(f"final full-graph accuracy: "
          f"{float(trainer.eval_fn(state.params, graph)):.4f}")


if __name__ == "__main__":
    main()
