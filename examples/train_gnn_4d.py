"""End-to-end driver: 4D-parallel ScaleGNN training to a target accuracy.

This is the paper's full system — communication-free distributed sampling,
3D PMM with layer rotation, data parallelism, and the §V optimizations —
running on a 16-device host mesh (G_d=2 x 2x2x2 grid).

    XLA_FLAGS=--xla_force_host_platform_device_count=16 \
    PYTHONPATH=src python examples/train_gnn_4d.py
"""
import os
import subprocess
import sys

if len(os.environ.get("XLA_FLAGS", "")) == 0:
    # be forgiving: re-exec ourselves with the device flag set
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    raise SystemExit(subprocess.call([sys.executable] + sys.argv, env=env))

sys.argv = [sys.argv[0], "--dataset", "ogbn-products",
            "--vertices", "4096", "--gd", "2", "--g", "2",
            "--batch", "512", "--steps", "200", "--dropout", "0.2",
            "--bf16-collectives", "--prefetch",
            "--target-acc", "0.93"]
from repro.launch.train import main   # noqa: E402
main()
