"""End-to-end driver: 4D-parallel ScaleGNN training to a target accuracy.

This is the paper's full system — communication-free distributed sampling,
3D PMM with layer rotation, data parallelism, and the §V optimizations —
running on a 16-device host mesh (G_d=2 x 2x2x2 grid) through the
``repro.train`` runtime: 8-step scan chunks with the §V-A prefetch carry
folded into the scan state, and one eval per report boundary.

    XLA_FLAGS=--xla_force_host_platform_device_count=16 \
    PYTHONPATH=src python examples/train_gnn_4d.py
"""
import os
import subprocess
import sys

if len(os.environ.get("XLA_FLAGS", "")) == 0:
    # be forgiving: re-exec ourselves with the device flag set
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    raise SystemExit(subprocess.call([sys.executable] + sys.argv, env=env))

from repro.launch.train import main   # noqa: E402

main(["--dataset", "ogbn-products",
      "--vertices", "4096", "--gd", "2", "--g", "2",
      "--batch", "512", "--steps", "200", "--dropout", "0.2",
      "--bf16-collectives", "--prefetch", "--chunk-size", "8",
      "--eval-every", "24", "--target-acc", "0.93"])
